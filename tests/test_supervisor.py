"""Fault-tolerance supervisor tests."""
import os
import sys

import pytest

from repro.launch.supervisor import run_with_restarts, supervise


def test_run_with_restarts_retries():
    calls = []

    def flaky(attempt):
        calls.append(attempt)
        if attempt < 2:
            raise RuntimeError("simulated node failure")

    used = run_with_restarts(flaky, max_restarts=3, log=lambda *_: None)
    assert used == 2
    assert calls == [0, 1, 2]


def test_run_with_restarts_exhausts():
    def always_fails(attempt):
        raise RuntimeError("dead")

    with pytest.raises(RuntimeError):
        run_with_restarts(always_fails, max_restarts=2, log=lambda *_: None)


def test_supervise_restarts_until_success(tmp_path):
    """Child crashes twice (via a state file) then succeeds — the
    process-level restart path used for real node failures."""
    marker = tmp_path / "attempts"
    script = (
        "import os, sys\n"
        f"p = {str(marker)!r}\n"
        "n = int(open(p).read()) if os.path.exists(p) else 0\n"
        "open(p, 'w').write(str(n + 1))\n"
        "sys.exit(0 if n >= 2 else 1)\n"
    )
    rc = supervise([sys.executable, "-c", script], max_restarts=5,
                   backoff_s=0.0, log=lambda *_: None)
    assert rc == 0
    assert int(marker.read_text()) == 3


def test_supervise_gives_up(tmp_path):
    rc = supervise([sys.executable, "-c", "import sys; sys.exit(3)"],
                   max_restarts=1, backoff_s=0.0, log=lambda *_: None)
    assert rc != 0


def test_supervise_kills_hung_child():
    rc = supervise(
        [sys.executable, "-c",
         "import time; print('x', flush=True); time.sleep(600)"],
        max_restarts=0, hang_timeout=2.0, backoff_s=0.0,
        log=lambda *_: None)
    assert rc != 0


def test_heartbeat_pattern_ignores_chatty_output():
    """A child logging constantly but never emitting the heartbeat line
    is a wedged server (device call never returns while admission logs
    keep flowing) — with --heartbeat-regex it must be killed, because
    chatty output no longer counts as progress."""
    rc = supervise(
        [sys.executable, "-u", "-c",
         "import time\n"
         "while True:\n"
         "    print('admitting request ...', flush=True)\n"
         "    time.sleep(0.2)\n"],
        max_restarts=0, hang_timeout=2.0, backoff_s=0.0,
        heartbeat_pattern=r"\[serve\] heartbeat", log=lambda *_: None)
    assert rc != 0


def test_heartbeat_pattern_keeps_live_child():
    """Heartbeat lines (and only those) reset the hang timer: a child
    heartbeating slower than the chatty noise but faster than the
    timeout survives to a clean exit."""
    rc = supervise(
        [sys.executable, "-u", "-c",
         "import time\n"
         "for i in range(4):\n"
         "    print('[serve] heartbeat step=%d' % i, flush=True)\n"
         "    time.sleep(0.8)\n"],
        max_restarts=0, hang_timeout=2.5, backoff_s=0.0,
        heartbeat_pattern=r"\[serve\] heartbeat", log=lambda *_: None)
    assert rc == 0
