"""Self-speculative decoding (serve.speculative) + rank-truncated views.

Covers the zero-copy rank_truncated_view (buffer identity, static
EffRank marker, jit-cache sharing), the rank-r' == rmask-zeroed-full
property across the plain / merged-QKV / expert-grid / non-divisible-TP
fallback launches, the PagedKVState reserve/trim rollback primitives,
multi-token paged attention vs sequential single-token decode, and the
engine-level guarantees: greedy token identity vs the plain engine
(exact and truncated drafts), rollback page-leak regression with uid
reuse under an overcommitted pool, gating errors, and the dynamic-k
controller.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import run_multidevice

from repro.kernels import ops, ref
from repro.models import transformer as T
from repro.quant.surgery import (EffRank, _stack_group,
                                 abstract_quantized_params,
                                 rank_truncated_view, truncated_rank)
from repro.serve import (InferenceEngine, PagedKVState, Request,
                         ServeConfig)

_POLICIES = [ops.KernelPolicy(mode="ref"),
             ops.KernelPolicy(mode="pallas", interpret=True)]
_IDS = ["ref", "pallas"]


def _mk_lowrank(m, k, n, r, dtype=jnp.float32, seed=0):
    key = jax.random.PRNGKey(seed)
    kx, ku, kv, k1, k2 = jax.random.split(key, 5)
    x = jax.random.normal(kx, (m, k), jnp.float32).astype(dtype)
    u = jnp.sign(jax.random.normal(ku, (n, r)))
    v = jnp.sign(jax.random.normal(kv, (k, r)))
    qu_t = ref.pack_signs(jnp.where(u == 0, 1.0, u).T)
    qv = ref.pack_signs(jnp.where(v == 0, 1.0, v))
    s1 = jnp.abs(jax.random.normal(k1, (n,))) + 0.1
    s2 = jnp.abs(jax.random.normal(k2, (k,))) + 0.1
    return x, qv, qu_t, s1, s2


# ---------------------------------------------------------------------------
# rank_truncated_view: arithmetic, zero-copy, static marker
# ---------------------------------------------------------------------------


def test_truncated_rank_arithmetic():
    assert truncated_rank(96, 1.0) == 96
    assert truncated_rank(96, 0.5) == 32       # floor to rank_align
    assert truncated_rank(96, 0.75) == 64
    assert truncated_rank(96, 0.01) == 32      # clamped to one tile
    assert truncated_rank(32, 0.5) == 32       # never below align
    assert truncated_rank(128, 0.5) == 64


def test_view_is_zero_copy_and_static():
    _, qv, qu_t, s1, s2 = _mk_lowrank(4, 64, 64, 96)
    params = {"blk": {"wq": {"qv": qv, "qu_t": qu_t, "s1": s1, "s2": s2},
                      "norm": s1}}
    view = rank_truncated_view(params, 0.5)
    vq = view["blk"]["wq"]
    # every array leaf IS the original buffer — no copies, no slices
    for k in ("qv", "qu_t", "s1", "s2"):
        assert vq[k] is params["blk"]["wq"][k]
    assert view["blk"]["norm"] is params["blk"]["norm"]
    assert int(vq["eff_rank"]) == 48 // 32 * 32
    # EffRank is aux_data, not a traced leaf: same leaf count as params
    assert len(jax.tree.leaves(view)) == len(jax.tree.leaves(params))
    # full-rank fraction returns the very same dict objects
    full = rank_truncated_view(params, 1.0)
    assert full is params
    # equal fractions share one treedef => one jit cache entry
    t1 = jax.tree.structure(rank_truncated_view(params, 0.5))
    t2 = jax.tree.structure(rank_truncated_view(params, 0.5))
    assert t1 == t2
    assert t1 != jax.tree.structure(rank_truncated_view(params, 0.75))
    assert EffRank(64) == EffRank(64) and EffRank(64) != EffRank(32)
    with pytest.raises(ValueError):
        rank_truncated_view(params, 0.0)
    with pytest.raises(ValueError):
        rank_truncated_view(params, 1.5)


# ---------------------------------------------------------------------------
# property: rank-r' view == full model with trailing components zeroed
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("policy", _POLICIES, ids=_IDS)
def test_eff_rank_matches_rmask_zeroed_plain(policy):
    x, qv, qu_t, s1, s2 = _mk_lowrank(5, 64, 96, 96)
    rp = 32
    got = ops.lowrank_binary_matmul(x, qv, qu_t, s1, s2, policy=policy,
                                    eff_rank=rp)
    want = ref.lowrank_binary_matmul_fused_ref(
        x, qv, qu_t, s1, s2,
        rmask=(jnp.arange(96) < rp).astype(jnp.float32))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("policy", _POLICIES, ids=_IDS)
def test_eff_rank_matches_rmask_zeroed_merged(policy):
    # two sibling projections with DIFFERENT true ranks: the view's
    # eff_rank composes with the pad-rank rmask of the merged layout
    x, qv_a, qu_a, s1_a, s2_a = _mk_lowrank(4, 64, 96, 96, seed=1)
    _, qv_b, qu_b, s1_b, s2_b = _mk_lowrank(4, 64, 64, 64, seed=2)
    subs = [{"qv": qv_a, "qu_t": qu_a, "s1": s1_a, "s2": s2_a},
            {"qv": qv_b, "qu_t": qu_b, "s1": s1_b, "s2": s2_b}]
    mp = _stack_group(subs)                     # padded R = 96
    view = rank_truncated_view({"wqkv": mp}, 0.75)["wqkv"]
    rp = int(view["eff_rank"])
    assert rp == 64
    outs = ops.lowrank_binary_matmul_merged(x, mp, (96, 64),
                                            policy=policy, eff_rank=rp)
    cut = (jnp.arange(96) < rp).astype(jnp.float32)
    for i, (sub, n) in enumerate(zip(subs, (96, 64))):
        want = ref.lowrank_binary_matmul_fused_ref(
            x, mp["qv"][i], mp["qu_t"][i], mp["s1"][i], mp["s2"][i],
            rmask=mp["rmask"][i] * cut)[:, :n]
        np.testing.assert_allclose(np.asarray(outs[i]),
                                   np.asarray(want),
                                   rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("policy", _POLICIES, ids=_IDS)
def test_eff_rank_matches_rmask_zeroed_expert(policy):
    E, C, K, N, R = 3, 4, 64, 64, 96
    packs = [_mk_lowrank(C, K, N, R, seed=7 + e) for e in range(E)]
    x = jnp.stack([p[0] for p in packs])
    qv = jnp.stack([p[1] for p in packs])
    qu_t = jnp.stack([p[2] for p in packs])
    s1 = jnp.stack([p[3] for p in packs])
    s2 = jnp.stack([p[4] for p in packs])
    rp = 64
    got = ops.lowrank_binary_matmul_expert(x, qv, qu_t, s1, s2,
                                           policy=policy, eff_rank=rp)
    cut = (jnp.arange(R) < rp).astype(jnp.float32)
    for e in range(E):
        want = ref.lowrank_binary_matmul_fused_ref(
            x[e], qv[e], qu_t[e], s1[e], s2[e], rmask=cut)
        np.testing.assert_allclose(np.asarray(got[e]), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)


def test_eff_rank_tp_nondivisible_fallback():
    # d_out=76 is not divisible by tp=2: _tp_lowrank declines and the
    # launch falls back to the local kernel — eff_rank must survive the
    # fallback. d_out=96 goes through the sharded launch for contrast.
    out = run_multidevice("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.kernels import ops, ref
        mesh = jax.make_mesh((2,), ("model",))
        pol = ops.KernelPolicy(mode="pallas", interpret=True, mesh=mesh)
        key = jax.random.PRNGKey(3)
        for n in (76, 96):
            kx, ku, kv, k1, k2 = jax.random.split(
                jax.random.fold_in(key, n), 5)
            x = jax.random.normal(kx, (4, 64), jnp.float32)
            u = jnp.sign(jax.random.normal(ku, (n, 96)))
            v = jnp.sign(jax.random.normal(kv, (64, 96)))
            qu_t = ref.pack_signs(jnp.where(u == 0, 1.0, u).T)
            qv = ref.pack_signs(jnp.where(v == 0, 1.0, v))
            s1 = jnp.abs(jax.random.normal(k1, (n,))) + 0.1
            s2 = jnp.abs(jax.random.normal(k2, (64,))) + 0.1
            got = ops.lowrank_binary_matmul(x, qv, qu_t, s1, s2,
                                            policy=pol, tp="col",
                                            eff_rank=64)
            want = ref.lowrank_binary_matmul_fused_ref(
                x, qv, qu_t, s1, s2,
                rmask=(jnp.arange(96) < 64).astype(jnp.float32))
            np.testing.assert_allclose(np.asarray(got),
                                       np.asarray(want),
                                       rtol=1e-5, atol=1e-5)
        print("TP_FALLBACK_OK")
    """, devices=2)
    assert "TP_FALLBACK_OK" in out


# ---------------------------------------------------------------------------
# PagedKVState: reserve_rows / trim (the rollback primitives)
# ---------------------------------------------------------------------------


def test_reserve_rows_and_trim(tiny_dense_cfg):
    kv = PagedKVState(tiny_dense_cfg, max_batch=2, max_len=32,
                      page_size=8, n_pages=7)
    kv.admit(0, 5)                                   # 1 page
    assert kv.used_pages == 1
    assert kv.reserve_rows(0, 17)                    # rows 0..16: 3 pages
    assert kv.used_pages == 3
    assert kv.reserve_rows(0, 17) and kv.used_pages == 3    # idempotent
    # trim back to 6 committed rows: keep ceil(6/8)=1 page, free 2
    assert kv.trim(0, 6) == 2
    assert kv.used_pages == 1
    assert (kv.tables["linear"][0, 1:] == 0).all()
    assert kv.trim(0, 6) == 0                        # nothing to drop
    # freed pages are reusable by another slot
    kv.admit(1, 30)                                  # 4 pages
    assert kv.used_pages == 5
    # pool exhaustion: reserve fails but partial mapping sticks, and a
    # retry after pages free up completes the reservation
    assert not kv.reserve_rows(0, 32)
    kv.release(1)
    assert kv.reserve_rows(0, 32) and kv.used_pages == 4
    kv.release(0)
    assert kv.used_pages == 0
    assert (kv.tables["linear"] == 0).all()


def test_rollback_then_redraft_same_page(tiny_dense_cfg):
    """Mid-page reject: trimming draft rows that live on the committed
    page must free nothing and keep the mapping intact, and the next
    draft cycle reserves straight back into the SAME page (no
    alloc/free churn inside a page)."""
    kv = PagedKVState(tiny_dense_cfg, max_batch=1, max_len=32,
                      page_size=8, n_pages=5)
    kv.admit(0, 3)                             # 3 committed rows, page A
    assert kv.used_pages == 1
    assert kv.reserve_rows(0, 3 + 4)           # draft k=4: rows 3..6
    assert kv.used_pages == 1                  # still inside page A
    before = np.asarray(kv.tables["linear"][0]).copy()
    assert kv.trim(0, 4) == 0                  # accept 1, reject 3
    assert (np.asarray(kv.tables["linear"][0]) == before).all()
    assert kv.reserve_rows(0, 4 + 4)           # redraft: rows 4..7
    assert kv.used_pages == 1                  # same page reused
    assert (np.asarray(kv.tables["linear"][0]) == before).all()
    # a draft that crossed into a fresh page: reject past the boundary
    # frees the overflow page, redraft re-allocates one
    assert kv.reserve_rows(0, 8 + 4)           # rows 8..11: page B
    assert kv.used_pages == 2
    assert kv.trim(0, 8) == 1                  # reject all of page B
    assert kv.used_pages == 1
    assert kv.reserve_rows(0, 8 + 4) and kv.used_pages == 2
    kv.release(0)
    assert kv.used_pages == 0


@pytest.mark.parametrize("policy", _POLICIES, ids=_IDS)
@pytest.mark.parametrize("S", [1, 3])
def test_rollback_stale_rows_never_read(policy, S):
    """After a rollback the pool still holds the rejected drafts' KV
    past the live position — the kernel's position reconstruction must
    exclude them. Kernel on the dirty pool == oracle on a pool with
    every stale row zeroed (random stale values would shift the
    softmax if they leaked in)."""
    rng = np.random.default_rng(40 + S)
    B, Hq, Hkv, D, PS, pages = 2, 4, 2, 16, 4, 3
    NP = B * pages + 1
    rows = pages * PS
    kp = rng.standard_normal((NP, PS, Hkv, D)).astype(np.float32)
    vp = rng.standard_normal((NP, PS, Hkv, D)).astype(np.float32)
    q = jnp.asarray(rng.standard_normal((B, S, Hq, D)), jnp.float32)
    bt = np.arange(1, NP).reshape(B, pages).astype(np.int32)
    # live span p .. p+S-1 (linear, no wrap); rows past it are stale
    p = np.asarray([3, PS - 1], np.int32)
    kc, vc = kp.copy(), vp.copy()
    for b in range(B):
        for r in range(int(p[b]) + S, rows):
            pg, off = bt[b, r // PS], r % PS
            kc[pg, off] = 0.0
            vc[pg, off] = 0.0
    q_pos = jnp.asarray(p, jnp.int32)
    got = ops.paged_attention(q, jnp.asarray(kp), jnp.asarray(vp),
                              jnp.asarray(bt), q_pos, q_pos,
                              scale=0.25, policy=policy)
    want = ref.paged_attention_ref(q, jnp.asarray(kc), jnp.asarray(vc),
                                   jnp.asarray(bt), q_pos, q_pos,
                                   scale=0.25)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# multi-token paged attention == sequential single-token decode
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("policy", _POLICIES, ids=_IDS)
def test_multitoken_paged_attention_matches_sequential(policy):
    B, S, Hq, Hkv, D, ps, pages = 2, 3, 4, 2, 16, 4, 9
    key = jax.random.PRNGKey(11)
    kq, kk, kv_ = jax.random.split(key, 3)
    q = jax.random.normal(kq, (B, S, Hq, D), jnp.float32)
    k_pool = jax.random.normal(kk, (pages, ps, Hkv, D), jnp.float32)
    v_pool = jax.random.normal(kv_, (pages, ps, Hkv, D), jnp.float32)
    table = np.zeros((B, 4), np.int32)
    table[0, :3] = [1, 2, 3]
    table[1, :3] = [4, 5, 6]
    table = jnp.asarray(table)
    # first query positions: slot 0 at 5, slot 1 at 9 (page-boundary
    # straddle: 9..11 spans rows 9,10,11 across pages 2 and 3)
    pos = jnp.asarray([5, 9], jnp.int32)
    got = ops.paged_attention(q, k_pool, v_pool, table, pos, pos,
                              policy=policy)
    for j in range(S):
        want_j = ops.paged_attention(q[:, j:j + 1], k_pool, v_pool,
                                     table, pos + j, pos + j,
                                     policy=policy)
        np.testing.assert_allclose(np.asarray(got[:, j]),
                                   np.asarray(want_j[:, 0]),
                                   rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# engine: greedy token identity, rollback, gating, dynamic k
# ---------------------------------------------------------------------------


def _prompts(vocab, lens, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, vocab, size=(n,)).astype(np.int32)
            for n in lens]


def _serve(params, cfg, prompts, budgets, scfg, max_batch=2, max_len=48,
           uids=None):
    eng = InferenceEngine(params, cfg, scfg, max_batch=max_batch,
                          max_len=max_len)
    for uid, (p, b) in zip(uids or range(len(prompts)),
                           zip(prompts, budgets)):
        eng.submit(Request(uid, p, max_new_tokens=b))
    done = eng.run()
    return {u: r.output for u, r in done.items()}, eng


def _random_packed(cfg, seed=0, target_bpw=2.0):
    """Random-valued packed params in the real quantized layout (rank
    64 at bpw 2 for the 64x64 tiny linears — big enough to truncate).
    Logits are junk, but the engine serves them deterministically: a
    genuinely-different truncated draft exercises reject + rollback
    while identity must still hold. Scales are UNIT (a dominant random
    s1 row would make the argmax truncation-invariant — acceptance 1.0
    — and the rollback path would never fire)."""
    tpl = abstract_quantized_params(cfg, target_bpw=target_bpw)
    rng = np.random.default_rng(seed)

    def fill(path, s):
        last = getattr(path[-1], "key", str(path[-1]))
        if s.dtype == jnp.uint32:
            return jnp.asarray(rng.integers(
                0, 2**32, size=s.shape, dtype=np.uint64).astype(np.uint32))
        if last in ("s1", "s2"):
            return jnp.ones(s.shape, s.dtype)
        return jnp.asarray(rng.normal(0, 0.05, s.shape).astype(s.dtype))

    return jax.tree_util.tree_map_with_path(fill, tpl)


def test_spec_identity_fp_full_rank(tiny_dense_cfg, tiny_params):
    # FP params carry no packed dicts: the view IS the params, every
    # draft verifies, and acceptance is exactly 1.0
    cfg, params = tiny_dense_cfg, tiny_params
    prompts = _prompts(cfg.vocab_size, [5, 9, 3])
    budgets = [12, 8, 14]
    base = ServeConfig(greedy=True, page_size=8, prefix_cache=False)
    plain, _ = _serve(params, cfg, prompts, budgets, base)
    spec_cfg = dataclasses.replace(base, spec_rank_frac=1.0, spec_k=4)
    spec, eng = _serve(params, cfg, prompts, budgets, spec_cfg)
    for u in plain:
        np.testing.assert_array_equal(plain[u], spec[u])
    assert eng.spec is not None
    assert eng.spec.draft_params is eng.params          # zero-copy
    assert eng.spec.acceptance_rate() == 1.0
    assert eng.stats["spec_rollback_tokens"] == 0
    # k+1 tokens per cycle => far fewer device calls than tokens
    n_tok = sum(len(v) for v in spec.values())
    assert eng.stats["decode_steps"] < n_tok
    assert eng.kv.used_pages == 0


def test_spec_identity_truncated_draft_with_rollback(tiny_dense_cfg):
    cfg = tiny_dense_cfg
    params = _random_packed(cfg)
    prompts = _prompts(cfg.vocab_size, [6, 11, 4], seed=3)
    budgets = [10, 8, 12]
    base = ServeConfig(greedy=True, page_size=8, prefix_cache=False)
    plain, _ = _serve(params, cfg, prompts, budgets, base)
    spec_cfg = dataclasses.replace(base, spec_rank_frac=0.5, spec_k=4)
    spec, eng = _serve(params, cfg, prompts, budgets, spec_cfg)
    for u in plain:
        np.testing.assert_array_equal(plain[u], spec[u])
    # the rank-32 draft of a random rank-64 model disagrees often:
    # rejects (and page rollback accounting) must actually fire
    assert eng.stats["spec_rollback_tokens"] > 0
    assert eng.stats["spec_draft_tokens"] == \
        eng.stats["spec_accepted_tokens"] + \
        eng.stats["spec_rollback_tokens"]
    assert eng.kv.used_pages == 0
    assert (eng.kv.tables["linear"] == 0).all()


def test_spec_rollback_never_leaks_pages_uid_reuse(tiny_dense_cfg):
    # overcommitted pool: reservation preempts mid-flight slots while
    # rollback trims draft pages — after two full drains with REUSED
    # uids, every page must be home and outputs must reproduce
    cfg = tiny_dense_cfg
    params = _random_packed(cfg, seed=5)
    prompts = _prompts(cfg.vocab_size, [8, 8, 8, 8], seed=9)
    budgets = [12, 12, 12, 12]
    scfg = ServeConfig(greedy=True, page_size=8, kv_pool_pages=10,
                       prefix_cache=False, spec_rank_frac=0.5, spec_k=4)
    first, eng1 = _serve(params, cfg, prompts, budgets, scfg,
                         max_batch=3, max_len=32)
    assert eng1.kv.used_pages == 0, "drained engine must hold no pages"
    assert (eng1.kv.tables["linear"] == 0).all()
    second, eng2 = _serve(params, cfg, prompts, budgets, scfg,
                          max_batch=3, max_len=32,
                          uids=[0, 1, 2, 3])
    for u in first:
        np.testing.assert_array_equal(first[u], second[u])
    assert eng2.kv.used_pages == 0
    assert eng2.kv.free_pages == eng1.kv.free_pages


def test_spec_gating_errors(tiny_dense_cfg, tiny_params):
    cfg, params = tiny_dense_cfg, tiny_params

    def build(**kw):
        return InferenceEngine(params, cfg,
                               ServeConfig(**{"greedy": True,
                                              "page_size": 8, **kw}),
                               max_batch=2, max_len=32)

    with pytest.raises(ValueError, match="greedy"):
        build(greedy=False, spec_rank_frac=0.5)
    with pytest.raises(ValueError, match="paged"):
        build(paged=False, spec_rank_frac=0.5)
    with pytest.raises(ValueError, match="spec_rank_frac"):
        build(spec_rank_frac=1.5)
    with pytest.raises(ValueError, match="spec_k"):
        build(spec_rank_frac=0.5, spec_k=2, spec_k_min=3)


def test_spec_dynamic_k_shrinks_on_low_acceptance(tiny_dense_cfg):
    cfg = tiny_dense_cfg
    params = _random_packed(cfg, seed=1)
    prompts = _prompts(cfg.vocab_size, [6, 6], seed=2)
    scfg = ServeConfig(greedy=True, page_size=8, spec_rank_frac=0.5,
                       spec_k=4, spec_k_min=1)
    _, eng = _serve(params, cfg, prompts, [16, 16], scfg)
    # near-zero acceptance on the random model: the EMA controller must
    # have walked k down from its ceiling
    assert eng.spec.acceptance_rate() < 0.5
    assert eng.spec.k < eng.spec.k_max
    assert eng.spec.k >= eng.spec.k_min
    # per-uid accounting covers exactly the submitted requests
    assert set(eng.spec.acceptance) == {0, 1}


# ---------------------------------------------------------------------------
# bf16 vs f32 greedy argmax divergence under TP=2 (docs/serving.md)
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_bf16_tp2_argmax_divergence_rate():
    # teacher-forced per-position argmax, TP=2 vs single-device: f32
    # must match exactly (reassociation-safe reductions at this scale);
    # bf16 may flip near-ties — the measured rate is recorded in
    # docs/serving.md §Tensor-parallel serving
    out = run_multidevice("""
        import dataclasses
        import jax, jax.numpy as jnp, numpy as np
        from repro.models import transformer as T
        from repro.models.config import ModelConfig
        from repro.serve import InferenceEngine, ServeConfig
        from repro.launch.mesh import make_serving_mesh

        B, S = 4, 48
        mesh = make_serving_mesh(2)
        toks = jnp.asarray(np.random.default_rng(0).integers(
            0, 256, size=(B, S)), jnp.int32)
        for dtype in ("float32", "bfloat16"):
            cfg = ModelConfig(name="tiny", family="dense", n_layers=2,
                              d_model=64, n_heads=4, n_kv_heads=2,
                              d_ff=128, vocab_size=256, loss_chunk=0,
                              remat=False, dtype=dtype)
            params = T.init_params(jax.random.PRNGKey(0), cfg)
            scfg = ServeConfig(greedy=True, paged=False)
            preds = []
            for m in (None, mesh):
                eng = InferenceEngine(params, cfg, scfg, max_batch=B,
                                      max_len=S + 1, mesh=m)

                def fwd(p, t, cache):
                    with eng._trace_scope():
                        h, _ = T._cached_forward(p, cfg, t, cache, 0)
                        return T.logits_fn(p, cfg, h)

                lg = jax.jit(fwd)(eng.params, toks, eng.cache)
                preds.append(np.asarray(
                    jnp.argmax(lg.astype(jnp.float32), axis=-1)))
            rate = float((preds[0] != preds[1]).mean())
            print(f"DIVERGENCE {dtype} {rate:.6f}")
            if dtype == "float32":
                assert rate == 0.0, "f32 TP must be argmax-identical"
    """, devices=2)
    assert "DIVERGENCE float32 0.000000" in out
    assert "DIVERGENCE bfloat16" in out
