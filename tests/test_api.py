"""``repro.api`` facade: registries, NanoQuantModel lifecycle, and
explicit kernel policy."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.data import calib_batches
from repro.models import transformer as T

_FAST = dict(admm_iters=4, t_pre=2, t_post=2, t_glob=2, rank_align=32,
             min_dim=32)


@pytest.fixture(scope="module")
def tiny_quantized():
    cfg = api.get_smoke("qwen1.5-0.5b")
    cfg = dataclasses.replace(cfg, name="api-tiny")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    calib = calib_batches(cfg, 4, 32, batch=2)
    model = api.NanoQuantModel.quantize(params, cfg, calib,
                                        api.QuantConfig(**_FAST),
                                        verbose=False)
    return cfg, calib, model


# ---------------------------------------------------------------------------
# registries
# ---------------------------------------------------------------------------


def test_unknown_init_method_lists_available():
    with pytest.raises(KeyError) as exc:
        api.get_init_method("no_such_init")
    msg = str(exc.value)
    for name in ("lb_admm", "dual_svid", "dbf_admm"):
        assert name in msg
    assert "no_such_init" in msg


def test_unknown_arch_lists_available():
    with pytest.raises(KeyError) as exc:
        api.get_arch("no-such-arch")
    msg = str(exc.value)
    assert "llama3.2-1b" in msg and "no-such-arch" in msg
    # the configs-package delegation surfaces the same error
    from repro import configs
    with pytest.raises(KeyError):
        configs.get_config("no-such-arch")


def test_unknown_init_method_fails_inside_pipeline():
    cfg = api.get_smoke("llama3.2-1b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    calib = calib_batches(cfg, 2, 32, batch=2)
    qcfg = api.QuantConfig(init_method="bogus", **_FAST)
    with pytest.raises(KeyError, match="bogus"):
        api.nanoquant_quantize(params, cfg, calib, qcfg, verbose=False)


def test_register_custom_init_method_threads_through_pipeline():
    @api.register_init_method("test_zero_lowrank")
    def zero_init(w, d_in, d_out, *, rank, admm, key):
        din, dout = w.shape
        return {"lu": jnp.ones((dout, rank)), "lv": jnp.ones((din, rank)),
                "s1": jnp.zeros((dout,)), "s2": jnp.zeros((din,))}

    try:
        assert "test_zero_lowrank" in api.list_init_methods()
        cfg = api.get_smoke("llama3.2-1b")
        params = T.init_params(jax.random.PRNGKey(0), cfg)
        calib = calib_batches(cfg, 2, 32, batch=2)
        qcfg = api.QuantConfig(init_method="test_zero_lowrank",
                               admm_iters=0, t_pre=0, t_post=0, t_glob=0,
                               rank_align=32, min_dim=32)
        model = api.NanoQuantModel.quantize(params, cfg, calib, qcfg,
                                            verbose=False)
        # zero scales => every packed linear contributes exactly 0
        lp0 = jax.tree.map(lambda l: l[0], model.params["layers"])
        assert float(jnp.abs(lp0["attn"]["wq"]["s1"]).max()) == 0.0
    finally:
        api.INIT_METHODS.unregister("test_zero_lowrank")


def test_register_duplicate_rejected():
    reg = api.Registry("thing")
    reg.register("a", object())
    with pytest.raises(ValueError, match="already registered"):
        reg.register("a", object())
    reg.register("a", object(), overwrite=True)


def test_register_custom_arch():
    cfg = api.get_smoke("llama3.2-1b")

    @api.register_arch("test-custom-arch")
    def _spec():
        return api.ArchSpec("test-custom-arch", cfg, cfg, ("train_4k",))

    try:
        assert api.get_config("test-custom-arch") is cfg
        assert api.shapes_for("test-custom-arch") == ["train_4k"]
    finally:
        api.ARCHS.unregister("test-custom-arch")


# ---------------------------------------------------------------------------
# NanoQuantModel lifecycle
# ---------------------------------------------------------------------------


def test_save_load_roundtrip(tmp_path, tiny_quantized):
    cfg, calib, model = tiny_quantized
    out = str(tmp_path / "artifact")
    model.save(out)

    loaded = api.NanoQuantModel.load(out)
    assert loaded.cfg == cfg
    assert loaded.qcfg == model.qcfg
    assert loaded.ranks == model.ranks and loaded.ranks
    # packed params preserved exactly (dtypes + bits)
    la, lb = jax.tree.leaves(model.params), jax.tree.leaves(loaded.params)
    assert len(la) == len(lb)
    for a, b in zip(la, lb):
        assert np.asarray(a).dtype == np.asarray(b).dtype
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_loaded_model_generates(tmp_path, tiny_quantized):
    cfg, calib, model = tiny_quantized
    out = str(tmp_path / "artifact")
    model.save(out)
    loaded = api.NanoQuantModel.load(out)
    prompts = [np.arange(6, dtype=np.int32), np.arange(9, dtype=np.int32)]
    outs = loaded.generate(prompts, max_new_tokens=4, max_batch=2)
    assert len(outs) == 2
    assert all(o.shape == (4,) for o in outs)
    assert np.isfinite(loaded.perplexity(calib))


def test_load_missing_manifest_raises(tmp_path):
    with pytest.raises(FileNotFoundError, match="manifest|artifact"):
        api.NanoQuantModel.load(str(tmp_path))


def test_fp_artifact_roundtrip(tmp_path):
    cfg = api.get_smoke("llama3.2-1b")
    params = T.init_params(jax.random.PRNGKey(1), cfg)
    out = str(tmp_path / "fp")
    api.NanoQuantModel.from_fp(params, cfg).save(out)
    loaded = api.NanoQuantModel.load(out)
    assert not loaded.quantized
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(loaded.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_size_report_matches_surgery(tiny_quantized):
    cfg, _, model = tiny_quantized
    q = model.qcfg
    direct = api.packed_model_bytes(cfg, q.target_bpw, q.min_dim,
                                    q.rank_align)
    assert model.size_report() == direct


# ---------------------------------------------------------------------------
# kernel policy
# ---------------------------------------------------------------------------


def test_kernel_policy_scoped_override_restores():
    before = api.current_kernel_policy()
    with api.kernel_policy("ref") as p:
        assert p.mode == "ref"
        assert api.current_kernel_policy() is p
        with api.kernel_policy(api.KernelPolicy(mode="pallas")):
            assert api.current_kernel_policy().mode == "pallas"
        assert api.current_kernel_policy() is p
    assert api.current_kernel_policy() == before


def test_kernel_policy_set_returns_previous():
    from repro.kernels import ops
    before = ops.current_kernel_policy()
    prev = ops.set_kernel_policy(api.KernelPolicy(mode="ref"))
    try:
        assert prev == before
        assert ops.current_kernel_policy().mode == "ref"
    finally:
        ops.set_kernel_policy(before)


def test_set_kernel_policy_visible_across_threads():
    import threading
    from repro.kernels import ops
    before = ops.set_kernel_policy(api.KernelPolicy(mode="ref"))
    try:
        seen = []
        t = threading.Thread(
            target=lambda: seen.append(ops.current_kernel_policy().mode))
        t.start()
        t.join()
        assert seen == ["ref"]      # process-wide, not context-local
    finally:
        ops.set_kernel_policy(before)


def test_kernel_policy_invalid_mode_rejected():
    with pytest.raises(ValueError, match="unknown kernel mode"):
        api.KernelPolicy(mode="cuda")


def test_explicit_policy_argument_wins():
    from repro.kernels import ref
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (2, 64))
    u = jnp.sign(jax.random.normal(jax.random.PRNGKey(1), (96, 32)))
    u = jnp.where(u == 0, 1.0, u)
    v = jnp.sign(jax.random.normal(jax.random.PRNGKey(2), (64, 32)))
    v = jnp.where(v == 0, 1.0, v)
    qu_t, qv = ref.pack_signs(u.T), ref.pack_signs(v)
    s1, s2 = jnp.ones((96,)), jnp.ones((64,))
    with api.kernel_policy("ref"):
        y_ref = api.lowrank_binary_matmul(x, qv, qu_t, s1, s2)
        y_pal = api.lowrank_binary_matmul(
            x, qv, qu_t, s1, s2, policy=api.KernelPolicy(mode="pallas"))
    np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_pal),
                               rtol=1e-4, atol=1e-4)


def test_deprecated_mode_shims_warn_exactly_once():
    import warnings as _warnings
    from repro.kernels import ops
    before = ops.current_kernel_policy()
    ops._SHIM_WARNED.clear()
    try:
        with pytest.warns(DeprecationWarning):
            with ops.kernel_mode("ref"):
                assert ops.current_kernel_policy().mode == "ref"
        assert ops.current_kernel_policy() == before
        with pytest.warns(DeprecationWarning):
            ops.set_kernel_mode("pallas")
        assert ops.current_kernel_policy().mode == "pallas"
        # second use of either shim is silent (warn exactly once)
        with _warnings.catch_warnings():
            _warnings.simplefilter("error", DeprecationWarning)
            with ops.kernel_mode("ref"):
                pass
            ops.set_kernel_mode("ref")
    finally:
        ops.set_kernel_policy(before)
        ops._SHIM_WARNED.clear()
