"""Fault-tolerant distributed-style training: checkpoint/restart with a
simulated crash, deterministic data skip, and binary low-rank gradient
compression with error feedback (the paper's factorization reused as a
DP-collective compressor).

    PYTHONPATH=src python examples/fault_tolerant_train.py
"""
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro import api
from repro.data import train_iterator
from repro.launch.supervisor import run_with_restarts
from repro.train import TrainConfig, Trainer


def main():
    cfg = api.get_smoke("mamba2-370m")
    tcfg = TrainConfig(lr=2e-3, warmup=10, total_steps=120,
                       compress_grads=True, compress_rank=2)
    ckpt_dir = tempfile.mkdtemp(prefix="nq_ft_")
    print(f"checkpoints -> {ckpt_dir}")

    target_steps = 90
    crash_at = {0: 35, 1: 70}          # attempt -> step to "crash" at

    def attempt(n):
        mgr = api.CheckpointManager(ckpt_dir, keep=2)
        start = mgr.latest_step() or 0
        it = train_iterator(cfg, batch=8, seq=48, start_step=start)
        tr = Trainer(cfg, tcfg, it, mgr, ckpt_every=10, log_every=10)
        tr.restore_or_init()
        budget = target_steps - tr.step
        if n in crash_at:
            budget = min(budget, crash_at[n] - tr.step)
        tr.run(max(budget, 0))
        if n in crash_at and tr.step < target_steps:
            raise RuntimeError(f"simulated node failure at step {tr.step}")
        print(f"[attempt {n}] reached step {tr.step}")

    restarts = run_with_restarts(attempt, max_restarts=4)
    print(f"\ntraining survived {restarts} simulated failures; "
          f"resume was deterministic (same data stream, same schedule).")


if __name__ == "__main__":
    main()
