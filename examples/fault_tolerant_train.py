"""Fault tolerance end to end: (1) checkpoint/restart training with a
simulated crash and deterministic data skip, then (2) the *real*
quantization resume path — the run is killed mid-pipeline (twice),
restarted with ``resume=True`` against its per-block journal, and the
final artifact is proven bit-identical (manifest hash + leaf crc32s) to
an uninterrupted run. See docs/quantization.md.

    PYTHONPATH=src python examples/fault_tolerant_train.py
"""
import hashlib
import json
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro import api
from repro.checkpoint.journal import _crc_leaves
from repro.data import calib_batches, train_iterator
from repro.launch.supervisor import run_with_restarts
from repro.train import TrainConfig, Trainer


def train_with_crashes(cfg):
    """Part 1: training survives two simulated node failures."""
    tcfg = TrainConfig(lr=2e-3, warmup=10, total_steps=120,
                       compress_grads=True, compress_rank=2)
    ckpt_dir = tempfile.mkdtemp(prefix="nq_ft_")
    print(f"checkpoints -> {ckpt_dir}")

    target_steps = 90
    crash_at = {0: 35, 1: 70}          # attempt -> step to "crash" at

    def attempt(n):
        mgr = api.CheckpointManager(ckpt_dir, keep=2)
        start = mgr.latest_step() or 0
        it = train_iterator(cfg, batch=8, seq=48, start_step=start)
        tr = Trainer(cfg, tcfg, it, mgr, ckpt_every=10, log_every=10)
        tr.restore_or_init()
        budget = target_steps - tr.step
        if n in crash_at:
            budget = min(budget, crash_at[n] - tr.step)
        tr.run(max(budget, 0))
        if n in crash_at and tr.step < target_steps:
            raise RuntimeError(f"simulated node failure at step {tr.step}")
        print(f"[attempt {n}] reached step {tr.step}")
        return tr.state[0]

    restarts = run_with_restarts(attempt, max_restarts=4)
    print(f"training survived {restarts} simulated failures; "
          f"resume was deterministic (same data stream, same schedule).")
    mgr = api.CheckpointManager(ckpt_dir, keep=2)
    it = train_iterator(cfg, batch=8, seq=48,
                        start_step=mgr.latest_step() or 0)
    tr = Trainer(cfg, tcfg, it, mgr)
    tr.restore_or_init()
    return tr.state[0]


def manifest_hash(artifact_dir):
    """sha256 of the saved manifest, wall time excluded (the one field
    that legitimately differs between an interrupted and a clean run)."""
    with open(os.path.join(artifact_dir, api.MANIFEST_NAME)) as f:
        m = json.load(f)
    m.get("report", {}).pop("wall_s", None)
    return hashlib.sha256(
        json.dumps(m, sort_keys=True).encode()).hexdigest()


def quantize_with_crashes(cfg, params):
    """Part 2: the pipeline is killed twice mid-run and resumed from
    its journal; the artifact must match an uninterrupted run exactly."""
    calib = calib_batches(cfg, 8, 48, batch=4)
    qcfg = api.QuantConfig(target_bpw=1.0, admm_iters=8, t_pre=4,
                           t_post=6, t_glob=4, min_dim=32)
    journal_dir = tempfile.mkdtemp(prefix="nq_journal_")
    print(f"\nquantization journal -> {journal_dir}")

    # crash when block 1, then block 2, starts computing; a resumed
    # (journaled) block never re-crashes, so each attempt progresses
    plans = [api.QuantFaultPlan([api.QuantFault(block=1,
                                                kind="crash_block")]),
             api.QuantFaultPlan([api.QuantFault(block=2,
                                                kind="crash_block")])]

    result = {}

    def attempt(n):
        faults = plans[n] if n < len(plans) else None
        model = api.NanoQuantModel.quantize(
            params, cfg, calib, qcfg, verbose=False,
            journal_dir=journal_dir, resume=True, faults=faults,
            heartbeat=lambda m: print(f"[quant] heartbeat {m}"))
        result["model"] = model

    restarts = run_with_restarts(attempt, max_restarts=4)
    print(f"quantization survived {restarts} injected crashes")

    resumed_dir = tempfile.mkdtemp(prefix="nq_art_resumed_")
    result["model"].save(resumed_dir)

    # the ground truth: one uninterrupted run, no journal
    clean = api.NanoQuantModel.quantize(params, cfg, calib, qcfg,
                                        verbose=False)
    clean_dir = tempfile.mkdtemp(prefix="nq_art_clean_")
    clean.save(clean_dir)

    h_resumed, h_clean = manifest_hash(resumed_dir), manifest_hash(clean_dir)
    c_resumed = _crc_leaves(result["model"].params)
    c_clean = _crc_leaves(clean.params)
    print(f"manifest sha256 (resumed) : {h_resumed[:16]}...")
    print(f"manifest sha256 (clean)   : {h_clean[:16]}...")
    print(f"leaf crc32 (resumed/clean): {c_resumed:#010x} / {c_clean:#010x}")
    assert h_resumed == h_clean, "manifest mismatch after resume"
    assert c_resumed == c_clean, "packed leaves mismatch after resume"
    print("kill -> resume artifact is bit-identical to the clean run.")


def main():
    cfg = api.get_smoke("mamba2-370m")
    params = train_with_crashes(cfg)
    quantize_with_crashes(cfg, params)


if __name__ == "__main__":
    main()
