"""Continuous-batching serving of a NanoQuant-packed model through the
``repro.api`` facade: quantize a teacher, then drive the slot-scheduled
``InferenceEngine`` with a stream of mixed-length requests — the
end-to-end inference driver (paper §4.4 deployment scenario).

    PYTHONPATH=src python examples/serve_quantized.py
    PYTHONPATH=src python examples/serve_quantized.py --engine wave

``--engine wave`` reproduces the legacy drain-then-refill BatchServer
schedule over the same engine, for comparison.
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro import api
from repro.data import calib_batches
from repro.models import transformer as T


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--engine", default="continuous",
                    choices=["continuous", "wave"])
    args = ap.parse_args()

    cfg = api.get_smoke("qwen3-4b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)

    print("[1/3] quantizing to 1 bit (fast settings)...")
    calib = calib_batches(cfg, 8, 48, batch=4)
    qcfg = api.QuantConfig(admm_iters=10, t_pre=4, t_post=6, t_glob=4,
                           min_dim=32)
    model = api.NanoQuantModel.quantize(params, cfg, calib, qcfg,
                                        verbose=False)

    print(f"[2/3] starting inference engine "
          f"(max_batch=4, admission={args.engine})...")
    eng = model.engine(api.ServeConfig(max_new_tokens=16, temperature=0.8,
                                       top_k=32),
                       max_batch=4, max_len=64, admission=args.engine)
    rng = np.random.default_rng(0)
    n_req = 12
    handles = []
    streamed = []
    for uid in range(n_req):
        prompt = rng.integers(0, cfg.vocab_size,
                              size=(8 + uid % 5,)).astype(np.int32)
        # request 0 streams per-token through a callback
        cb = (lambda u, t: streamed.append(int(t))) if uid == 0 else None
        handles.append(eng.submit(
            api.Request(uid, prompt, max_new_tokens=8 + uid % 9),
            on_token=cb))

    print("[3/3] serving...")
    t0 = time.time()
    done = eng.run()
    dt = time.time() - t0
    total = sum(len(r.output) for r in done.values())
    lats = np.asarray(sorted(h.latency for h in handles))
    print(f"\nserved {len(done)} requests / {total} tokens in {dt:.1f}s "
          f"({total/dt:.1f} tok/s incl. compile)")
    print(f"latency: mean {lats.mean():.2f}s  p95 "
          f"{np.percentile(lats, 95):.2f}s; wasted slot-steps "
          f"{eng.stats['wasted_slot_steps']}; prefill compilations "
          f"{eng.stats['prefill_traces']}")
    print(f"req 0 streamed tokens: {streamed}")
    for uid in sorted(done)[:3]:
        print(f"  req {uid}: prompt[:4]={done[uid].prompt[:4]} -> "
              f"output={done[uid].output[:8]}")


if __name__ == "__main__":
    main()
