"""Batched serving of a NanoQuant-packed model through the ``repro.api``
facade: quantize a teacher, then drive the wave-scheduled BatchServer
with a stream of requests — the end-to-end inference driver (paper §4.4
deployment scenario).

    PYTHONPATH=src python examples/serve_quantized.py
"""
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro import api
from repro.data import calib_batches
from repro.models import transformer as T


def main():
    cfg = api.get_smoke("qwen3-4b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)

    print("[1/3] quantizing to 1 bit (fast settings)...")
    calib = calib_batches(cfg, 8, 48, batch=4)
    qcfg = api.QuantConfig(admm_iters=10, t_pre=4, t_post=6, t_glob=4,
                           min_dim=32)
    model = api.NanoQuantModel.quantize(params, cfg, calib, qcfg,
                                        verbose=False)

    print("[2/3] starting batch server (max_batch=4)...")
    srv = model.server(api.ServeConfig(max_new_tokens=16, temperature=0.8,
                                       top_k=32),
                       max_batch=4, max_len=64)
    rng = np.random.default_rng(0)
    n_req = 12
    for uid in range(n_req):
        prompt = rng.integers(0, cfg.vocab_size,
                              size=(8 + uid % 5,)).astype(np.int32)
        srv.submit(api.Request(uid, prompt, max_new_tokens=8 + uid % 9))

    print("[3/3] serving...")
    t0 = time.time()
    done = srv.run()
    dt = time.time() - t0
    total = sum(len(r.output) for r in done.values())
    print(f"\nserved {len(done)} requests / {total} tokens "
          f"in {dt:.1f}s (incl. compile)")
    for uid in sorted(done)[:3]:
        print(f"  req {uid}: prompt[:4]={done[uid].prompt[:4]} -> "
              f"output={done[uid].output[:8]}")


if __name__ == "__main__":
    main()
