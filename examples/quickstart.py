"""Quickstart: the full ``repro.api`` lifecycle on CPU in a few minutes —
train a small FP teacher, quantize it with NanoQuant to 1 bit, save the
packed artifact, load it back, generate, and compare perplexities.

    PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro import api
from repro.data import SyntheticCorpus, calib_batches, train_iterator
from repro.train import TrainConfig, Trainer


def main():
    # 1. a reduced llama3.2-style config (the full config is what the
    #    dry-run lowers at scale; api.list_archs() names all 10)
    cfg = api.get_smoke("llama3.2-1b")
    print(f"model: {cfg.name}  (family={cfg.family}, "
          f"{cfg.param_count()/1e6:.2f}M params)")

    # 2. train the FP teacher on the synthetic corpus
    tcfg = TrainConfig(lr=2e-3, warmup=20, total_steps=200)
    trainer = Trainer(cfg, tcfg, train_iterator(cfg, batch=8, seq=64),
                      log_every=50)
    trainer.restore_or_init()
    trainer.run(200)
    params = trainer.state[0]

    corpus = SyntheticCorpus(cfg.vocab_size)
    evalb = calib_batches(cfg, 12, 64, seed=999, corpus=corpus)
    ppl_fp = api.NanoQuantModel.from_fp(params, cfg).perplexity(evalb)

    # 3. NanoQuant PTQ (paper Alg. 1): calibrate -> block reconstruction
    #    (LB-ADMM init + STE refinement) -> scale-only KD
    calib = calib_batches(cfg, 16, 64, corpus=corpus)
    qcfg = api.QuantConfig(target_bpw=1.0, admm_iters=20, t_pre=8,
                           t_post=12, t_glob=8, min_dim=32)
    model = api.NanoQuantModel.quantize(params, cfg, calib, qcfg)
    ppl_q = model.perplexity(evalb)

    # 4. persist + reload: the artifact is self-describing (manifest
    #    carries configs + ranks), so load needs only the directory
    out = tempfile.mkdtemp(prefix="nq_quickstart_")
    model.save(out)
    reloaded = api.NanoQuantModel.load(out)

    # 5. generate from the packed model
    prompts = [np.arange(8, dtype=np.int32), np.arange(12, dtype=np.int32)]
    outs = reloaded.generate(prompts, max_new_tokens=8)

    # 6. results
    sizes = reloaded.size_report()
    print("\n=== quickstart results ===")
    print(f"FP16 teacher ppl : {ppl_fp:.3f}")
    print(f"NanoQuant ppl    : {ppl_q:.3f}   (target 1.0 bit/weight)")
    print(f"linears bpw      : {sizes['linears_bpw']:.3f} "
          f"(wall {model.report['wall_s']:.0f}s, "
          f"{len(model.ranks)} layers factorized)")
    print(f"artifact         : {out} (manifest + packed checkpoint)")
    print(f"generated        : {[o.tolist() for o in outs]}")


if __name__ == "__main__":
    main()
