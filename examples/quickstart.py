"""Quickstart: train a small FP teacher, quantize it with NanoQuant to
1 bit, and compare perplexities + packed size — the paper's pipeline
end-to-end in a few minutes on CPU.

    PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro import configs
from repro.core.packing import packed_nbytes
from repro.core.pipeline import QuantConfig, nanoquant_quantize
from repro.data import SyntheticCorpus, calib_batches, train_iterator
from repro.data.synthetic import eval_perplexity
from repro.models import transformer as T
from repro.train import TrainConfig, Trainer


def main():
    # 1. a reduced llama3.2-style config (the full config is what the
    #    dry-run lowers at scale; --arch selects any of the 10)
    cfg = configs.get_smoke("llama3.2-1b")
    print(f"model: {cfg.name}  (family={cfg.family}, "
          f"{cfg.param_count()/1e6:.2f}M params)")

    # 2. train the FP teacher on the synthetic corpus
    tcfg = TrainConfig(lr=2e-3, warmup=20, total_steps=200)
    trainer = Trainer(cfg, tcfg, train_iterator(cfg, batch=8, seq=64),
                      log_every=50)
    trainer.restore_or_init()
    trainer.run(200)
    params = trainer.state[0]

    corpus = SyntheticCorpus(cfg.vocab_size)
    evalb = calib_batches(cfg, 12, 64, seed=999, corpus=corpus)
    ppl_fp = eval_perplexity(T.loss_fn, params, cfg, evalb)

    # 3. NanoQuant PTQ (paper Alg. 1): calibrate -> block reconstruction
    #    (LB-ADMM init + STE refinement) -> scale-only KD
    calib = calib_batches(cfg, 16, 64, corpus=corpus)
    qcfg = QuantConfig(target_bpw=1.0, admm_iters=20, t_pre=8, t_post=12,
                       t_glob=8, min_dim=32)
    qparams, report = nanoquant_quantize(params, cfg, calib, qcfg)
    ppl_q = eval_perplexity(T.loss_fn, qparams, cfg, evalb)

    # 4. results
    packed = sum(packed_nbytes(lin) for lin in _packed_linears(qparams))
    print("\n=== quickstart results ===")
    print(f"FP16 teacher ppl : {ppl_fp:.3f}")
    print(f"NanoQuant ppl    : {ppl_q:.3f}   (target 1.0 bit/weight)")
    print(f"packed linears   : {packed/1e6:.2f} MB "
          f"(wall {report['wall_s']:.0f}s, "
          f"{len(report['ranks'])} layers factorized)")


def _packed_linears(tree):
    if isinstance(tree, dict):
        if "qu_t" in tree:
            yield tree
        else:
            for v in tree.values():
                yield from _packed_linears(v)


if __name__ == "__main__":
    main()
